"""Allocator: §III-A equal-step-time solve, Eq. 1 dataset split, privacy
placement, capacity row masks.

The hypothesis-based property tests over randomized clusters live in
tests/test_properties.py (guarded by ``pytest.importorskip``) so this
module stays runnable without the optional ``[test]`` extra.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.allocator import assign_private, retune, row_mask, solve
from repro.core.speed_model import SpeedModel


def saturating(vmax, b_half, bs=(8, 16, 32, 64, 128, 256)):
    bs = np.asarray(bs, float)
    return SpeedModel(bs, vmax * bs / (bs + b_half))


class TestSolve:
    def test_identical_nodes_get_identical_batches(self):
        sm = saturating(34.2, 18.0)
        plan = solve({f"n{i}": (1, sm) for i in range(3)}, 30_000)
        bs = plan.batch_sizes()
        assert len(set(bs.values())) == 1

    def test_lead_group_is_most_influential(self):
        fast, slow = saturating(100.0, 10.0), saturating(2.0, 1.0)
        # 36 slow nodes out-influence 1 fast node (36*2 < 100 -> fast leads)
        plan = solve({"host": (1, fast), "csd": (36, slow)}, 10_000)
        knee = fast.knee()
        assert plan.batch_sizes()["host"] == knee

    def test_equal_step_time_within_tolerance(self):
        fast, slow = saturating(100.0, 10.0), saturating(20.0, 5.0)
        plan = solve({"a": (1, fast), "b": (1, slow)}, 10_000)
        times = [g.speed_model.step_time(g.batch_size) for g in plan.groups]
        assert max(times) / min(times) < 1.10   # no rank stall > 10%

    def test_max_batch_cap_respected(self):
        sm = saturating(34.2, 18.0)
        plan = solve({"h": (1, sm, 100)}, 10_000)
        assert plan.batch_sizes()["h"] <= 100


class TestEq1:
    """Dataset_i = BS_i/ΣBS × Dataset;  N_steps = Dataset / ΣBS."""

    def test_steps_per_epoch_exact(self):
        sm = saturating(34.2, 18.0)
        plan = solve({f"n{i}": (1, sm) for i in range(3)}, 300_000)
        total_bs = plan.global_batch
        assert plan.steps_per_epoch == 300_000 // total_bs

    def test_ranges_cover_dataset_disjointly(self):
        fast, slow = saturating(100.0, 10.0), saturating(20.0, 5.0)
        plan = solve({"a": (2, fast), "b": (3, slow)}, 12_345)
        spans = sorted(plan.ranges.values())
        assert spans[0][0] == 0
        assert spans[-1][1] == 12_345
        for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
            assert a1 == b0                     # contiguous, no gap/overlap

    def test_ranges_proportional_to_batch_share(self):
        fast, slow = saturating(100.0, 10.0), saturating(20.0, 5.0)
        plan = solve({"a": (1, fast), "b": (1, slow)}, 100_000)
        for g in plan.groups:
            lo, hi = plan.ranges[g.name]
            share = g.batch_size * g.count / plan.global_batch
            assert (hi - lo) / 100_000 == pytest.approx(share, abs=1e-3)


class TestRetune:
    def test_retune_changes_only_named_group(self):
        sm = saturating(34.2, 18.0)
        plan = solve({f"n{i}": (1, sm) for i in range(3)}, 30_000)
        old = plan.batch_sizes()
        new = retune(plan, {"n1": old["n1"] // 2})
        got = new.batch_sizes()
        assert got["n1"] == old["n1"] // 2
        assert got["n0"] == old["n0"] and got["n2"] == old["n2"]

    def test_retune_clips_to_capacity(self):
        sm = saturating(34.2, 18.0)
        plan = solve({"a": (1, sm)}, 10_000)
        cap = plan.groups[0].capacity
        new = retune(plan, {"a": cap * 10})
        assert new.batch_sizes()["a"] == cap

    def test_retune_to_zero_masks_group_out(self):
        sm = saturating(34.2, 18.0)
        plan = solve({"a": (1, sm), "b": (1, sm)}, 10_000)
        new = retune(plan, {"a": 0})
        assert new.batch_sizes()["a"] == 0
        lo, hi = new.ranges["a"]
        assert hi - lo == 0                    # Eq. 1 gives it no data
        assert new.global_batch == new.batch_sizes()["b"]

    def test_retune_reassigns_ranges(self):
        sm = saturating(34.2, 18.0)
        plan = solve({"a": (1, sm), "b": (1, sm)}, 10_000)
        new = retune(plan, {"a": plan.batch_sizes()["a"] // 2})
        a_old = plan.ranges["a"][1] - plan.ranges["a"][0]
        a_new = new.ranges["a"][1] - new.ranges["a"][0]
        assert a_new < a_old


class TestRowMask:
    def test_mask_layout_blocks_of_capacity(self):
        sm = saturating(34.2, 18.0)
        plan = solve({"a": (2, sm), "b": (1, sm)}, 10_000)
        m = row_mask(plan)
        assert len(m) == plan.global_capacity
        assert m.sum() == plan.global_batch

    def test_mask_updates_on_retune_same_length(self):
        sm = saturating(34.2, 18.0)
        plan = solve({"a": (1, sm), "b": (1, sm)}, 10_000)
        m0 = row_mask(plan)
        new = retune(plan, {"a": plan.batch_sizes()["a"] - 7})
        m1 = row_mask(new)
        assert len(m0) == len(m1)              # static SPMD shapes
        assert m1.sum() == m0.sum() - 7

class TestPrivacy:
    def test_private_items_pinned_home(self):
        sm = saturating(34.2, 18.0)
        plan = solve({"a": (1, sm), "b": (1, sm)}, 1000)
        rng = np.random.default_rng(0)
        owners = rng.integers(0, 2, 1000)
        private = rng.random(1000) < 0.3
        out = assign_private(plan, owners, private)
        for gi, g in enumerate(plan.groups):
            mine = set(np.flatnonzero(private & (owners == gi)))
            assert mine.issubset(set(out[g.name]))
            other = set(np.flatnonzero(private & (owners != gi)))
            assert not (set(out[g.name]) & other)   # no foreign private data

    def test_every_item_assigned_exactly_once(self):
        sm = saturating(34.2, 18.0)
        plan = solve({"a": (1, sm), "b": (2, sm)}, 500)
        rng = np.random.default_rng(1)
        owners = rng.integers(0, 2, 500)
        private = rng.random(500) < 0.5
        out = assign_private(plan, owners, private)
        allidx = np.concatenate(list(out.values()))
        assert len(allidx) == 500
        assert len(set(allidx.tolist())) == 500

"""Queue-backed channel: a pair of ``multiprocessing.Queue``s.

The alternative transport for setups where a duplex pipe is awkward
(e.g. many-to-one fan-in, or a future cluster backend that replaces the
queues with a broker). Semantics match :class:`PipeChannel` except that
a dead peer cannot be detected from the transport itself — the runtime
already treats that as ordinary silence, so nothing above this layer
changes.
"""
from __future__ import annotations

import multiprocessing
import queue as _queue
from typing import Optional, Tuple

from repro.runtime.ipc.base import Channel, ChannelClosed
from repro.runtime.messages import Message, WireMessage


class QueueChannel(Channel):
    def __init__(self, inbox: "multiprocessing.Queue",
                 outbox: "multiprocessing.Queue") -> None:
        self._inbox = inbox
        self._outbox = outbox
        self._peeked: Optional[WireMessage] = None
        self._closed = False

    def put(self, message: Message) -> None:
        if self._closed:
            raise ChannelClosed("channel closed")
        self._outbox.put(message.to_wire())

    def poll(self, timeout: float = 0.0) -> bool:
        if self._peeked is not None:
            return True
        try:
            self._peeked = self._inbox.get(
                timeout=timeout) if timeout else self._inbox.get_nowait()
            return True
        except _queue.Empty:
            return False

    def get(self) -> Message:
        if self._peeked is None:
            self._peeked = self._inbox.get()
        wire, self._peeked = self._peeked, None
        return Message.from_wire(wire)

    def close(self) -> None:
        self._closed = True


def queue_pair() -> Tuple[QueueChannel, QueueChannel]:
    """(coordinator_end, worker_end) built from two mp queues."""
    to_worker: "multiprocessing.Queue" = multiprocessing.Queue()
    to_coord: "multiprocessing.Queue" = multiprocessing.Queue()
    return (QueueChannel(to_coord, to_worker),
            QueueChannel(to_worker, to_coord))

"""Telemetry for the control plane: one event stream for every producer.

Three producers previously hand-wired their own report plumbing:

  * ``core/simulator.py`` synthesized ``{group: {"speed": ...}}`` dicts
    and called the controller inline;
  * ``launch/train.py`` derived reports from real step timers (optionally
    interference-scaled) and threaded them through a separate
    ``HeartbeatMonitor``;
  * heartbeat liveness was a side channel that reached into the
    controller to mask groups out.

All three now speak :class:`StepReport` over a :class:`TelemetryBus`.
The bus is a per-step buffer + pub/sub tap: producers ``publish()``
reports as they measure them, the :class:`~repro.core.control.
control_plane.ControlPlane` drains the buffer once per step and derives
liveness (a group that stops publishing goes silent — no separate
heartbeat protocol). Subscribers (loggers, benchmarks) can observe the
raw stream without touching control flow.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.obs import NULL_TRACER

# subscriber failures recorded per bus, bounded so a subscriber that
# throws every step cannot grow memory for the whole run
_MAX_SUBSCRIBER_ERRORS = 256


@dataclasses.dataclass
class StepReport:
    """One group's measurement for one synchronous step.

    ``speed`` is the measured processing speed (img or samples /s) —
    Eq. 2's SP_i. ``cpu_util`` feeds the paper's third tuning method;
    ``power_w`` optionally overrides the static power model for
    energy-aware policies. An idle-but-alive group (b_g = 0) publishes
    its benchmark speed so rejoin logic can restore it at the knee.
    """

    step: int
    group: str
    speed: float
    cpu_util: Optional[float] = None
    power_w: Optional[float] = None

    @classmethod
    def from_legacy(cls, step: int, group: str,
                    report: Dict[str, float]) -> "StepReport":
        """Adapt the historical ``{"speed": ..., "cpu_util": ...}`` dict."""
        return cls(step=step, group=group, speed=float(report["speed"]),
                   cpu_util=(float(report["cpu_util"])
                             if "cpu_util" in report else None),
                   power_w=(float(report["power_w"])
                            if "power_w" in report else None))

    def as_legacy(self) -> Dict[str, float]:
        out = {"speed": self.speed}
        if self.cpu_util is not None:
            out["cpu_util"] = self.cpu_util
        if self.power_w is not None:
            out["power_w"] = self.power_w
        return out


def normalize_reports(step: int, reports) -> Dict[str, StepReport]:
    """Accept either ``{group: StepReport}`` or the legacy
    ``{group: {"speed": ...}}`` shape and return ``{group: StepReport}``."""
    out: Dict[str, StepReport] = {}
    for name, r in (reports or {}).items():
        if isinstance(r, StepReport):
            out[name] = r
        else:
            out[name] = StepReport.from_legacy(step, name, r)
    return out


class TelemetryBus:
    """Buffered pub/sub for :class:`StepReport` events.

    Producers call :meth:`publish` any time during a step; the consumer
    (the control plane) calls :meth:`drain` once per step and gets the
    latest report per group. ``last_seen`` survives drains — liveness is
    derived from it rather than from a separate heartbeat message type.

    Subscribers are OBSERVERS: an exception raised by one must never
    take down the publisher (the coordinator round) or starve the
    subscribers after it. ``publish`` isolates each call — failures are
    recorded in :attr:`errors` (bounded) and as ``error/subscriber``
    trace events when a tracer is attached, and never re-raised.
    """

    def __init__(self) -> None:
        self._pending: Dict[str, StepReport] = {}
        self._last_seen: Dict[str, int] = {}
        self._subscribers: List[Callable[[StepReport], None]] = []
        self.errors: List[Dict] = []
        self.tracer = NULL_TRACER

    # -- producer side --------------------------------------------------
    def publish(self, report: StepReport) -> None:
        self._pending[report.group] = report
        self._last_seen[report.group] = report.step
        for fn in self._subscribers:
            try:
                fn(report)
            except Exception as exc:          # noqa: BLE001 — observer fence
                detail = {"group": report.group, "step": report.step,
                          "subscriber": getattr(fn, "__qualname__",
                                                None) or repr(fn),
                          "error": repr(exc)}
                if len(self.errors) < _MAX_SUBSCRIBER_ERRORS:
                    self.errors.append(detail)
                if self.tracer:
                    self.tracer.instant("error", "subscriber", detail)

    def publish_step(self, step: int, reports) -> None:
        """Publish a whole step's worth of (possibly legacy) reports."""
        for rep in normalize_reports(step, reports).values():
            self.publish(rep)

    # -- consumer side --------------------------------------------------
    def drain(self) -> Dict[str, StepReport]:
        out = self._pending
        self._pending = {}
        return out

    def last_seen(self, group: str) -> Optional[int]:
        return self._last_seen.get(group)

    def note_seen(self, group: str, step: int) -> None:
        """Record liveness for a group without a full report (back-compat
        with HeartbeatMonitor.beat)."""
        self._last_seen[group] = step

    def subscribe(self, fn: Callable[[StepReport], None]) -> None:
        self._subscribers.append(fn)


class SeriesView:
    """Per-group (step, value) series accumulated from a bus
    subscription — the per-trial telemetry view behind the search
    layer's pruner (DESIGN.md §17).

    The bus's own buffer is drained once per step by the control plane;
    a pruner scoring a *rung* (a window of many steps) needs history,
    so this view tails the publish stream and keeps a bounded series
    per group. Purely observational: it never touches control flow,
    and its queries are pure functions of what was published — which is
    what lets the search trace stay identical between the simulator
    and the live runtime.
    """

    def __init__(self, bus: Optional[TelemetryBus] = None,
                 maxlen: int = 4096) -> None:
        self._series: Dict[str, List] = {}
        self.maxlen = int(maxlen)
        if bus is not None:
            bus.subscribe(self.on_report)

    def on_report(self, report: StepReport) -> None:
        series = self._series.setdefault(report.group, [])
        series.append((report.step, report.speed))
        if len(series) > self.maxlen:
            del series[:len(series) - self.maxlen]

    def series(self, group: str) -> List:
        return list(self._series.get(group, ()))

    def count(self, group: str) -> int:
        return len(self._series.get(group, ()))

    def last_step(self, group: str) -> Optional[int]:
        series = self._series.get(group)
        return series[-1][0] if series else None

    def window_mean(self, group: str, lo: int, hi: int) -> Optional[float]:
        """Mean value over steps in ``[lo, hi)``, or None when the group
        published nothing in the window (a pruner must treat that as
        "no evidence", never as a zero score)."""
        vals = [v for s, v in self._series.get(group, ())
                if lo <= s < hi]
        if not vals:
            return None
        return sum(vals) / len(vals)


class StepBuckets:
    """Out-of-order report assembly for bounded-staleness pacing.

    Under asynchronous (run-ahead) rounds the coordinator receives
    reports for several different steps interleaved: a worker with k
    grants in flight answers them back-to-back while the control plane
    is still processing an older round. This class buckets arrivals by
    their *stamped* step so control rounds can still run in order, each
    on a coherent per-step report set.

    The ``floor`` is the oldest step the consumer still cares about
    (the control round currently being assembled). Anything below it is
    stale — e.g. the post-SIGCONT backlog a resumed worker flushes — and
    is rejected rather than bucketed, mirroring the synchronous loop's
    ``msg.step != step`` filter. Duplicate (step, group) arrivals are
    first-wins: a re-delivered report can never clobber the one a
    control round may already have been decided on.
    """

    def __init__(self) -> None:
        self._buckets: Dict[int, Dict[str, object]] = {}
        self._floor = 0
        # depth observer (DESIGN.md §14): called with the number of
        # partially-assembled rounds after every mutation — the
        # coordinator wires it to a ``coord.bucket_depth`` gauge. None
        # (the default) keeps add/pop free of any observability cost.
        self.on_depth: Optional[Callable[[int], None]] = None

    @property
    def floor(self) -> int:
        return self._floor

    def restore_floor(self, floor: int) -> None:
        """Crash-resume (DESIGN.md §15): fast-forward the staleness
        filter to where the journaled run had advanced it, so a
        restarted coordinator rejects re-delivered reports for rounds
        the dead one already consumed."""
        self._floor = max(self._floor, int(floor))

    def discard_group(self, group: str, from_step: int) -> int:
        """Network-partition semantics (DESIGN.md §15): forget ``group``'s
        already-bucketed reports for steps >= ``from_step``. A severed
        link must behave exactly like the simulator's step-keyed Dropout
        even for run-ahead reports that beat the severing to the
        coordinator. Returns the number discarded."""
        n = 0
        for s, bucket in self._buckets.items():
            if s >= from_step and bucket.pop(group, None) is not None:
                n += 1
        if n and self.on_depth is not None:
            self.on_depth(len(self._buckets))
        return n

    def add(self, step: int, group: str, payload) -> bool:
        """Bucket one arrival. Returns False when it was stale (below
        the floor); duplicates are kept first-wins and return True."""
        if step < self._floor:
            return False
        self._buckets.setdefault(step, {}).setdefault(group, payload)
        if self.on_depth is not None:
            self.on_depth(len(self._buckets))
        return True

    def peek(self, step: int) -> Dict[str, object]:
        """The (possibly still incomplete) bucket for ``step``."""
        return self._buckets.get(step, {})

    def pop(self, step: int) -> Dict[str, object]:
        """Consume ``step``'s bucket and advance the floor past it —
        later arrivals for it (or anything older) are stale."""
        out = self._buckets.pop(step, {})
        self._floor = max(self._floor, step + 1)
        for s in [s for s in self._buckets if s < self._floor]:
            del self._buckets[s]
        if self.on_depth is not None:
            self.on_depth(len(self._buckets))
        return out

    def pending_steps(self) -> List[int]:
        return sorted(self._buckets)

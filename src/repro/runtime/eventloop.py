"""The Stannis coordinator: an event loop owning the control plane.

Per synchronous round the loop

  1. applies any scheduled fault-injection actions (kill / restart /
     suspend / resume, delegated to the execution manager);
  2. paces every live worker with a ``StepGrant`` (the coordinator owns
     the logical clock — workers stamp reports with the granted step);
  3. collects one ``StepReportMsg`` per granted worker, bounded by
     ``round_timeout``. A killed worker surfaces as channel EOF, a
     suspended worker as a timeout — EITHER WAY the bus simply receives
     nothing, and the existing ControlPlane liveness path masks the
     group out after ``liveness_timeout`` silent rounds. No failure
     message type exists anywhere in the protocol.
  4. publishes the round's reports on the ``TelemetryBus`` and runs one
     control round (rejoin -> policies -> liveness);
  5. broadcasts any plan change as a ``Retune`` message — workers flip
     their row mask, nothing recompiles — and measures propagation lag
     from the worker-echoed batch size.

Because pacing is a rendezvous (grant -> report), a fully-live cluster
runs with zero timeouts and the round sequence is deterministic: the
same scenario replayed through :class:`~repro.core.simulator.ClusterSim`
and through this loop produces the identical event stream
(tests/test_runtime*.py assert the paper's 180 -> 140 -> 100 Fig. 6
sequence through both).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

from repro.core.allocator import BatchPlan
from repro.core.control import ControlPlane, RetuneEvent, StepReport
from repro.runtime.ipc import ChannelClosed
from repro.runtime.managers.base import ExecutionManager
from repro.runtime.messages import (CheckpointAck, CheckpointRequest, Goodbye,
                                    Hello, Message, Retune, StepGrant,
                                    StepReportMsg)
from repro.runtime.worker import InterferenceSpec, WorkerSpec


@dataclasses.dataclass
class FaultAction:
    """One scheduled fault-injection action. ``action`` is one of
    "kill" | "restart" | "suspend" | "resume"."""

    step: int
    action: str
    group: str


@dataclasses.dataclass
class RoundStats:
    step: int
    n_reports: int
    latency_s: float
    event: Optional[str] = None


@dataclasses.dataclass
class RuntimeResult:
    rounds: int
    events: List[RetuneEvent]
    round_stats: List[RoundStats]
    wall_time: float
    reports_total: int
    retune_lags: List[int]               # rounds from decision to worker echo
    checkpoint_acks: List[CheckpointAck]

    def event_tuples(self):
        return [(e.step, e.group, e.old_batch, e.new_batch, e.reason)
                for e in self.events]

    @property
    def reports_per_s(self) -> float:
        return self.reports_total / max(self.wall_time, 1e-9)

    @property
    def mean_round_latency_s(self) -> float:
        if not self.round_stats:
            return 0.0
        return sum(r.latency_s for r in self.round_stats) / \
            len(self.round_stats)


def specs_from_plan(plan: BatchPlan,
                    interferences: Sequence = (),
                    dropouts: Sequence = (),
                    train: Optional[Dict] = None,
                    seed: int = 0) -> List[WorkerSpec]:
    """One WorkerSpec per plan group, carrying its benchmark table and
    its slice of the fault schedule. ``interferences``/``dropouts`` are
    the simulator's dataclasses — the runtime and ``ClusterSim`` consume
    the SAME scenario description (trace parity by construction)."""
    specs = []
    for g in plan.groups:
        ivs = [InterferenceSpec(iv.start_step, iv.end_step, iv.capacity,
                                iv.speed_cap)
               for iv in interferences if iv.group == g.name]
        sil = [(d.start_step, d.end_step)
               for d in dropouts if d.group == g.name]
        specs.append(WorkerSpec(
            group=g.name, batch_size=g.batch_size, capacity=g.capacity,
            count=g.count,
            speed_batches=[float(b) for b in g.speed_model.batch_sizes],
            speed_speeds=[float(s) for s in g.speed_model.speeds],
            interference=ivs, silence=sil,
            train=dict(train) if train else None, seed=seed))
    return specs


class EventLoop:
    def __init__(self, control_plane: ControlPlane,
                 manager: ExecutionManager,
                 round_timeout: float = 1.0) -> None:
        self.control_plane = control_plane
        self.manager = manager
        self.round_timeout = round_timeout
        self._ckpt_acks: List[CheckpointAck] = []
        self._awaiting_acks: set = set()
        self._pending_lag: Dict[str, tuple] = {}   # group -> (step, new_bs)
        self._lags: List[int] = []

    # ------------------------------------------------------------------
    def run(self, rounds: int, faults: Sequence[FaultAction] = (),
            checkpoint_every: int = 0,
            on_retune=None) -> RuntimeResult:
        cp = self.control_plane
        stats: List[RoundStats] = []
        reports_total = 0
        t_run = time.perf_counter()
        for step in range(rounds):
            t0 = time.perf_counter()
            self._apply_faults(step, faults)
            granted = self._grant(step)
            reports = self._collect(granted, step)
            reports_total += len(reports)
            for msg in reports.values():
                cp.bus.publish(StepReport(step, msg.group, msg.speed,
                                          cpu_util=msg.cpu_util,
                                          power_w=msg.power_w))
            event = cp.poll(step)
            if event is not None:
                self._broadcast_retune(step, event)
                if on_retune:
                    on_retune(event)
            if checkpoint_every and (step + 1) % checkpoint_every == 0:
                self._broadcast(CheckpointRequest(step))
                self._awaiting_acks = set(self.manager.live())
            stats.append(RoundStats(
                step, len(reports), time.perf_counter() - t0,
                None if event is None else
                f"{event.group}:{event.old_batch}->{event.new_batch}"
                f" ({event.reason})"))
        self._drain_acks()
        return RuntimeResult(rounds, list(cp.events), stats,
                             time.perf_counter() - t_run, reports_total,
                             list(self._lags), list(self._ckpt_acks))

    def shutdown(self) -> None:
        self.manager.shutdown()

    # ------------------------------------------------------------------
    def _apply_faults(self, step: int, faults: Sequence[FaultAction]) -> None:
        for f in faults:
            if f.step != step:
                continue
            if f.action == "kill":
                self.manager.kill(f.group)
            elif f.action == "suspend":
                self.manager.suspend(f.group)
            elif f.action == "resume":
                self.manager.resume(f.group)
            elif f.action == "restart":
                handle = self.manager.workers[f.group]
                spec = dataclasses.replace(
                    handle.spec,
                    batch_size=self.control_plane.plan.batch_sizes().get(
                        f.group, handle.spec.batch_size))
                self.manager.restart(f.group, spec)
            else:
                raise ValueError(f"unknown fault action: {f.action}")

    def _grant(self, step: int) -> List[str]:
        granted = []
        for name, handle in self.manager.live().items():
            try:
                handle.channel.put(StepGrant(step))
                granted.append(name)
            except ChannelClosed:
                self.manager.mark_dead(name)
        return granted

    def _collect(self, granted: List[str],
                 step: int) -> Dict[str, StepReportMsg]:
        """One report per granted worker, or silence by the deadline."""
        reports: Dict[str, StepReportMsg] = {}
        pending = set(granted)
        deadline = time.perf_counter() + self.round_timeout
        while pending and time.perf_counter() < deadline:
            progressed = False
            for name in sorted(pending):
                handle = self.manager.workers[name]
                if not handle.alive:
                    pending.discard(name)
                    continue
                try:
                    while handle.channel.poll(0.0):
                        msg = handle.channel.get()
                        progressed = True
                        if self._route(name, msg, step, reports):
                            pending.discard(name)
                            break
                except ChannelClosed:
                    self.manager.mark_dead(name)
                    pending.discard(name)
                    progressed = True
            if pending and not progressed:
                time.sleep(0.002)
        return reports

    def _route(self, name: str, msg: Message, step: int,
               reports: Dict[str, StepReportMsg]) -> bool:
        """Returns True when ``name``'s report for THIS round arrived."""
        if isinstance(msg, StepReportMsg):
            if msg.step != step:
                return False             # stale (e.g. post-resume backlog)
            reports[name] = msg
            lag = self._pending_lag.get(name)
            if lag is not None and msg.batch_size == lag[1]:
                self._lags.append(step - lag[0])
                self._pending_lag.pop(name)
            return True
        if isinstance(msg, CheckpointAck):
            self._ckpt_acks.append(msg)
            self._awaiting_acks.discard(name)
        elif isinstance(msg, Goodbye):
            self.manager.mark_dead(name)
            return True
        elif isinstance(msg, Hello):
            pass                         # late duplicate; handshake owns it
        return False

    def _drain_acks(self) -> None:
        """A CheckpointRequest broadcast on the FINAL round would
        otherwise never be answered in a _collect pass — drain the
        outstanding acks so the result reflects the workers' final
        state."""
        deadline = time.perf_counter() + self.round_timeout
        while self._awaiting_acks and time.perf_counter() < deadline:
            progressed = False
            for name in sorted(self._awaiting_acks):
                handle = self.manager.workers.get(name)
                if handle is None or not handle.alive:
                    self._awaiting_acks.discard(name)
                    break
                try:
                    while handle.channel.poll(0.0):
                        self._route(name, handle.channel.get(), -1, {})
                        progressed = True
                except ChannelClosed:
                    self.manager.mark_dead(name)
                    self._awaiting_acks.discard(name)
                    progressed = True
            if self._awaiting_acks and not progressed:
                time.sleep(0.002)

    def _broadcast_retune(self, step: int, event: RetuneEvent) -> None:
        self._broadcast(Retune(step, self.control_plane.plan.batch_sizes(),
                               group=event.group, reason=event.reason))
        self._pending_lag[event.group] = (step, event.new_batch)

    def _broadcast(self, msg: Message) -> None:
        for name, handle in self.manager.live().items():
            try:
                handle.channel.put(msg)
            except ChannelClosed:
                self.manager.mark_dead(name)

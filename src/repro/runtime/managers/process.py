"""Process-based execution manager: real workers, real faults.

Each node group runs in its own spawn-context process (spawn, not fork:
workers may initialize JAX, which must not inherit a forked runtime).
Specs travel as wire primitives and the transport Connection is
inherited through ``Process(args=...)`` — nothing closure-shaped
crosses the boundary.

Fault injection is the real thing:
  * ``kill``    — SIGKILL + join. The coordinator sees channel EOF and,
                  through bus silence, the liveness mask-out path.
  * ``suspend`` — SIGSTOP. The channel stays open but goes silent: the
                  exact failure mode of a wedged node, which only the
                  silence-derived liveness path can detect.
  * ``resume``  — SIGCONT. The worker drains its grant backlog (stale
                  reports are discarded by the event loop) and rejoins
                  at its knee.
"""
from __future__ import annotations

import multiprocessing
import os
import signal

from repro.runtime.ipc.pipe import PipeChannel
from repro.runtime.ipc.shm import shm_available
from repro.runtime.managers.base import ExecutionManager, WorkerHandle
from repro.runtime.worker import WorkerSpec, worker_entry


class SpawnedProcessFaults:
    """Shared fault surface for managers whose workers are spawn-context
    processes (``self._procs``: {group: Process}) — the SIGKILL + join,
    SIGSTOP/SIGCONT, and join-then-force-stop teardown semantics live
    here ONCE, for both the pipe (ProcessManager) and socket
    (SocketExecutionManager) transports."""

    _procs: dict

    def _kill_proc(self, group: str) -> None:
        proc = self._procs.get(group)
        if proc is not None and proc.is_alive():
            proc.kill()
            proc.join(timeout=10.0)

    def _signal_proc(self, group: str, sig: int) -> bool:
        """Signal the group's spawned process if it exists; False when
        the group has no local process (e.g. a standalone socket
        worker, which the caller cannot signal)."""
        proc = self._procs.get(group)
        if proc is None:
            return False
        if proc.pid and proc.is_alive():
            os.kill(proc.pid, sig)
        return True

    def _join_all(self) -> None:
        for proc in self._procs.values():
            proc.join(timeout=10.0)
            if proc.is_alive():                  # wedged: force-stop
                proc.kill()
                proc.join(timeout=5.0)


class ProcessManager(SpawnedProcessFaults, ExecutionManager):
    name = "process"

    def __init__(self, hello_timeout: float = 120.0, chaos=None) -> None:
        super().__init__(hello_timeout, chaos=chaos)
        self._ctx = multiprocessing.get_context("spawn")
        self._procs = {}

    def _launch(self, spec: WorkerSpec) -> WorkerHandle:
        if shm_available():
            # spawned workers share this host by construction: bulk
            # payloads (checkpoint state blobs) go through the
            # shared-memory ring, not the pipe (DESIGN.md §13)
            spec.bulk = "shm"
        coord_conn, worker_conn = self._ctx.Pipe()
        proc = self._ctx.Process(target=worker_entry,
                                 args=(spec.to_wire(), worker_conn),
                                 name=f"stannis-{spec.group}", daemon=True)
        proc.start()
        worker_conn.close()                      # child's end only
        self._procs[spec.group] = proc
        return WorkerHandle(spec, PipeChannel(coord_conn))

    def kill(self, group: str) -> None:
        self._kill_proc(group)
        self.mark_dead(group)

    def suspend(self, group: str) -> None:
        self._signal_proc(group, signal.SIGSTOP)

    def resume(self, group: str) -> None:
        self._signal_proc(group, signal.SIGCONT)

"""Pallas TPU flash attention (online softmax, VMEM-tiled).

Grid ``(B, Hq, Sq/bq, Sk/bk)`` — the last axis iterates sequentially on TPU,
so the (m, l, acc) running statistics live in VMEM scratch across KV blocks.
GQA is handled in the K/V index_map (query head -> kv head); causal and
sliding-window masking skip fully-masked KV blocks via ``pl.when``.

Layout contract (ops.py adapts): q (B, Hq, Sq, D), k/v (B, Hkv, Sk, D),
out (B, Hq, Sq, D). D is kept whole (64/128 both MXU-aligned);
bq/bk default to 128/512 so a block set {q, k, v, acc} of
(128+2*512)*128*4B ~ 0.6 MB sits comfortably in the ~16 MB VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, sliding_window: int,
                 block_q: int, block_k: int, num_kv_blocks: int,
                 seq_k: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = kj * block_k

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        allow = k_pos < seq_k                           # tail padding
        if causal:
            allow &= q_pos >= k_pos
        if sliding_window:
            allow &= (q_pos - k_pos) < sliding_window
        s = jnp.where(allow, s, NEG_INF)
        m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new
        acc_ref[...] = acc_prev * corr[:, None] + pv

    if causal or sliding_window:
        # Skip KV blocks that are entirely masked out.
        q_last = q_start + block_q - 1
        k_first = k_start
        live = q_last >= k_first if causal else True
        if sliding_window:
            k_last = k_start + block_k - 1
            live &= (q_start - k_last) < sliding_window
        pl.when(live)(_compute)
    else:
        _compute()

    @pl.when(kj == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,                 # (B, Hq, Sq, D)
    k: jnp.ndarray,                 # (B, Hkv, Sk, D)
    v: jnp.ndarray,                 # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    sliding_window: int = 0,
    block_q: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    # pad seqs to block multiples (masked out inside the kernel)
    pq = (-sq) % block_q
    pk = (-sk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = (sq + pq) // block_q
    nk = (sk + pk) // block_k
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal,
        sliding_window=sliding_window, block_q=block_q, block_k=block_k,
        num_kv_blocks=nk, seq_k=sk)

    out = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, i, j, group=group: (b_, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, i, j, group=group: (b_, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq + pq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # m
            pltpu.VMEM((block_q,), jnp.float32),      # l
            pltpu.VMEM((block_q, d), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq]

"""Seeded network-fault injection for the Stannis transports
(DESIGN.md §15).

:class:`ChaosChannel` wraps any :class:`~repro.runtime.ipc.base.Channel`
(pipe, queue, or socket — it only uses the Channel surface) and makes
the link misbehave on purpose: frames are dropped, delayed, duplicated,
reordered, or bit-corrupted per a :class:`ChaosSpec`, and a *partition*
silences the link entirely in both directions until healed. Every
decision is drawn from a :mod:`random` stream seeded with
``(spec.seed, group, direction)``, so a chaos run is reproducible: the
fault pattern is a pure function of the seed and the per-link frame
index, never of wall-clock time.

Placement: the coordinator-side manager wraps its end of each worker
channel as ``ReliableChannel(ChaosChannel(transport))`` — injection
sits BELOW the reliable session layer (``ipc/session.py``), so both
ends' session endpoints see genuine loss and heal it. One injector per
link covers both directions: outbound faults act on ``put`` (before
the transport), inbound faults act at ingest (after the transport,
before delivery). Outbound *corruption* is the one direction-asymmetric
fault: over a socket it is genuine bit corruption via
``SocketChannel.send_raw`` (the peer's decoder rejects the frame and
its bounded resync skips it); over pipes/queues — where there are no
payload bytes to flip — it degrades to an unknown-kind poison tuple
the peer's ``get`` surfaces as
:class:`~repro.runtime.ipc.base.CorruptFrame`. Either way the frame is
lost-but-loud, which is what the session layer heals.

Scripted windows reuse the ``core/interference.py`` window grammar
(``start_step <= step < end_step``), clocked by the latest
:class:`~repro.runtime.messages.StepGrant` the channel has carried —
the coordinator's logical clock, sniffed in passing. Partition windows
listed on the spec are NOT enforced here: the managers convert them to
round-exact ``partition``/``heal`` fault actions (the partition
scheduler), because the sniffed clock runs up to k grants ahead under
bounded staleness and parity demands round-exact severing.
"""
from __future__ import annotations

import dataclasses
import heapq
import random
import time
from collections import Counter, deque
from typing import Deque, List, Optional, Tuple

from repro.runtime.ipc.base import Channel, ChannelClosed, CorruptFrame
from repro.runtime.messages import Message, StepGrant

# how many consecutive undecodable frames a chaos-hardened transport
# tolerates before concluding the stream is truly unrecoverable
DEFAULT_RESYNC_BUDGET = 8


@dataclasses.dataclass
class ChaosRates:
    """Per-direction fault probabilities (independent draws per frame).
    ``delay`` is the probability a frame is held for ``delay_s``."""

    drop: float = 0.0
    dup: float = 0.0
    reorder: float = 0.0
    corrupt: float = 0.0
    delay: float = 0.0
    delay_s: float = 0.02

    def any(self) -> bool:
        return bool(self.drop or self.dup or self.reorder
                    or self.corrupt or self.delay)


@dataclasses.dataclass
class ChaosWindow:
    """Scripted burst riding the interference window grammar: the
    ``rates`` replace the spec's base rates (per direction) while
    ``start_step <= step < end_step`` on the sniffed grant clock."""

    start_step: int
    end_step: int
    send: ChaosRates = dataclasses.field(default_factory=ChaosRates)
    recv: ChaosRates = dataclasses.field(default_factory=ChaosRates)
    group: str = ""                      # "" = every group


@dataclasses.dataclass
class PartitionWindow:
    """Link severed for ``group`` in [start_step, end_step) — enforced
    by the managers' partition scheduler as round-exact fault actions,
    and mirrored in ``ClusterSim`` as a ``Dropout`` of the same span
    (a partitioned link and a silent worker are indistinguishable to
    the control plane, which is the parity oracle's whole point)."""

    group: str
    start_step: int
    end_step: int


@dataclasses.dataclass
class ChaosSpec:
    """The whole chaos configuration for one run. ``groups`` limits
    injection to the named groups (None = every link). A default spec
    (all rates zero) still activates the session layer — useful as
    "reliability on, no faults"."""

    seed: int = 0
    send: ChaosRates = dataclasses.field(default_factory=ChaosRates)
    recv: ChaosRates = dataclasses.field(default_factory=ChaosRates)
    windows: List[ChaosWindow] = dataclasses.field(default_factory=list)
    partitions: List[PartitionWindow] = dataclasses.field(
        default_factory=list)
    groups: Optional[Tuple[str, ...]] = None

    def applies_to(self, group: str) -> bool:
        return self.groups is None or group in self.groups

    def rates(self, direction: str, step: int, group: str) -> ChaosRates:
        """Effective rates for one frame: the innermost active scripted
        window wins, else the base rates. Same half-open grammar as
        ``core/interference.py``: ``start_step <= step < end_step``."""
        for w in reversed(self.windows):
            if (not w.group or w.group == group) \
                    and w.start_step <= step < w.end_step:
                return getattr(w, direction)
        return getattr(self, direction)

    @classmethod
    def parse(cls, text: str) -> "ChaosSpec":
        """CLI grammar (``--chaos``): comma-separated tokens.

          seed=7                      RNG seed
          drop=0.01                   rate, both directions
          send.dup=0.02 recv.drop=…   rate, one direction
          delay=0.05 delay_s=0.02     delay probability / hold time
          window=5-25:drop=1.0        scripted burst (rates after ':',
                                      both directions)
          partition=xeon1@20-26       partition window for one group
          groups=xeon0|xeon1          limit injection to these groups

        Example: ``seed=7,drop=0.01,dup=0.01,partition=xeon1@20-26``.
        """
        spec = cls()
        rate_names = {f.name for f in dataclasses.fields(ChaosRates)}
        for token in filter(None, (t.strip() for t in text.split(","))):
            key, sep, value = token.partition("=")
            if not sep:
                raise ValueError(f"bad chaos token {token!r}: "
                                 f"expected key=value")
            if key == "seed":
                spec.seed = int(value)
            elif key == "groups":
                spec.groups = tuple(filter(None, value.split("|")))
            elif key == "partition":
                group, sep, span = value.partition("@")
                start, sep2, end = span.partition("-")
                if not (sep and sep2):
                    raise ValueError(
                        f"bad partition {value!r}: expected "
                        f"group@start-end")
                spec.partitions.append(
                    PartitionWindow(group, int(start), int(end)))
            elif key == "window":
                span, sep, rates_text = value.partition(":")
                start, sep2, end = span.partition("-")
                if not (sep and sep2):
                    raise ValueError(
                        f"bad window {value!r}: expected "
                        f"start-end:rate=value[:rate=value...]")
                w = ChaosWindow(int(start), int(end))
                for part in filter(None, rates_text.split(":")):
                    rk, _, rv = part.partition("=")
                    if rk not in rate_names:
                        raise ValueError(f"unknown window rate {rk!r}")
                    setattr(w.send, rk, float(rv))
                    setattr(w.recv, rk, float(rv))
                spec.windows.append(w)
            elif "." in key:
                direction, _, rate = key.partition(".")
                if direction not in ("send", "recv") \
                        or rate not in rate_names:
                    raise ValueError(f"unknown chaos key {key!r}")
                setattr(getattr(spec, direction), rate, float(value))
            elif key in rate_names:
                setattr(spec.send, key, float(value))
                setattr(spec.recv, key, float(value))
            else:
                raise ValueError(f"unknown chaos key {key!r}")
        return spec


# queue marker: a synthetically-corrupted inbound frame, surfaced from
# get() as CorruptFrame in stream order
_CORRUPT_IN = object()


class ChaosChannel(Channel):
    """The fault injector. Wraps one transport channel; both directions
    of one link draw from their own seeded streams. Exactly five RNG
    draws happen per frame (drop, corrupt, delay, reorder, dup) so the
    fault pattern depends only on (seed, direction, frame index) — not
    on which faults happen to short-circuit."""

    def __init__(self, inner: Channel, spec: ChaosSpec, group: str) -> None:
        self.inner = inner
        self.spec = spec
        self.group = group
        base = f"{spec.seed}:{group}"
        self._rng_out = random.Random(base + ":send")
        self._rng_in = random.Random(base + ":recv")
        self._step = 0                   # sniffed StepGrant clock
        self._partitioned = False
        self._in_q: Deque = deque()
        self._hold_out: Optional[Message] = None    # reorder (send)
        self._hold_in: Optional[Message] = None     # reorder (recv)
        self._delayed_out: List[Tuple[float, int, Message]] = []
        self._delayed_in: List[Tuple[float, int, Message]] = []
        self._delay_tie = 0
        self._in_closed: Optional[ChannelClosed] = None
        self.stats: Counter = Counter()

    # -- partition scheduler hooks --------------------------------------
    def set_partitioned(self, severed: bool) -> None:
        self.stats["partitions" if severed else "heals"] += 1
        self._partitioned = severed
        if severed:
            # frames the injector itself was still holding (reorder /
            # delay) are in flight ON the link: a severed link kills
            # them too. The reliable session above retransmits them
            # after heal, so this is loss, never truncation.
            dropped = ((self._hold_out is not None)
                       + (self._hold_in is not None)
                       + len(self._delayed_out) + len(self._delayed_in))
            if dropped:
                self.stats["partition_dropped_inflight"] += dropped
            self._hold_out = self._hold_in = None
            self._delayed_out.clear()
            self._delayed_in.clear()

    @property
    def partitioned(self) -> bool:
        return self._partitioned

    def chaos_stats(self) -> dict:
        return dict(self.stats)

    # -- send path ------------------------------------------------------
    def put(self, message: Message) -> None:
        if isinstance(message, StepGrant) and message.step > self._step:
            self._step = message.step    # the link's logical clock
        self._flush_due_out()
        if self._partitioned:
            self.stats["partition_dropped_out"] += 1
            return
        rates = self.spec.rates("send", self._step, self.group)
        d_drop, d_corrupt, d_delay, d_reorder, d_dup = (
            self._rng_out.random() for _ in range(5))
        if not rates.any():
            self._send(message)
            return
        if d_drop < rates.drop:
            self.stats["dropped_out"] += 1
            return
        if d_corrupt < rates.corrupt:
            self.stats["corrupt_out"] += 1
            self._corrupt_out(message)
            return
        if d_delay < rates.delay:
            self.stats["delayed_out"] += 1
            self._delay_tie += 1
            heapq.heappush(self._delayed_out,
                           (time.monotonic() + rates.delay_s,
                            self._delay_tie, message))
            return
        if d_reorder < rates.reorder and self._hold_out is None:
            self.stats["reordered_out"] += 1
            self._hold_out = message     # released behind the next frame
            return
        self._send(message)
        if self._hold_out is not None:
            held, self._hold_out = self._hold_out, None
            self._send(held)
        if d_dup < rates.dup:
            self.stats["dup_out"] += 1
            self._send(message)

    def _send(self, message: Message) -> None:
        self.inner.put(message)

    def _corrupt_out(self, message: Message) -> None:
        """Lose the frame loudly: the peer sees a frame it cannot
        decode (never a silently-wrong message) and its bounded resync
        skips it."""
        send_raw = getattr(self.inner, "send_raw", None)
        if send_raw is not None:         # socket: real bit corruption
            from repro.runtime.ipc.socket import encode_frame
            frame = bytearray(encode_frame(
                message.to_wire(), self.inner.max_frame, self.inner.codec))
            # first payload byte -> 0xFF: undecodable under every codec
            # (bad utf-8 for json, unknown wire id for binary/msgpack);
            # flip a random later bit too, for realism
            frame[4] = 0xFF
            if len(frame) > 5:
                idx = 5 + self._rng_out.randrange(len(frame) - 5)
                frame[idx] ^= 1 << self._rng_out.randrange(8)
            send_raw(bytes(frame))
        else:                            # pipe/queue: poison wire tuple
            self.inner.put(_PoisonPill())

    def _flush_due_out(self) -> None:
        now = time.monotonic()
        while self._delayed_out and self._delayed_out[0][0] <= now:
            self._send(heapq.heappop(self._delayed_out)[2])

    # -- receive path ---------------------------------------------------
    def _ingest(self) -> None:
        """Drain whatever the transport has buffered, applying inbound
        faults frame by frame."""
        while self._in_closed is None and \
                (self.inner.has_buffered() or self.inner.poll(0.0)):
            try:
                msg = self.inner.get()
            except CorruptFrame:
                self._in_q.append(_CORRUPT_IN)
                continue
            except ChannelClosed as e:
                self._in_closed = e
                break
            if self._partitioned:
                self.stats["partition_dropped_in"] += 1
                continue
            rates = self.spec.rates("recv", self._step, self.group)
            d_drop, d_corrupt, d_delay, d_reorder, d_dup = (
                self._rng_in.random() for _ in range(5))
            if not rates.any():
                self._in_q.append(msg)
                continue
            if d_drop < rates.drop:
                self.stats["dropped_in"] += 1
                continue
            if d_corrupt < rates.corrupt:
                self.stats["corrupt_in"] += 1
                self._in_q.append(_CORRUPT_IN)
                continue
            if d_delay < rates.delay:
                self.stats["delayed_in"] += 1
                self._delay_tie += 1
                heapq.heappush(self._delayed_in,
                               (time.monotonic() + rates.delay_s,
                                self._delay_tie, msg))
                continue
            if d_reorder < rates.reorder and self._hold_in is None:
                self.stats["reordered_in"] += 1
                self._hold_in = msg
                continue
            self._in_q.append(msg)
            if self._hold_in is not None:
                held, self._hold_in = self._hold_in, None
                self._in_q.append(held)
            if d_dup < rates.dup:
                self.stats["dup_in"] += 1
                self._in_q.append(msg)

    def _release_due_in(self) -> None:
        now = time.monotonic()
        while self._delayed_in and self._delayed_in[0][0] <= now:
            self._in_q.append(heapq.heappop(self._delayed_in)[2])
        if self._in_closed is not None and self._hold_in is not None:
            # EOF flushes a reorder hold — no next frame will release it
            held, self._hold_in = self._hold_in, None
            self._in_q.append(held)

    def _service(self) -> None:
        self._flush_due_out()
        self._ingest()
        self._release_due_in()

    def poll(self, timeout: float = 0.0) -> bool:
        deadline = time.monotonic() + max(timeout, 0.0)
        while True:
            self._service()
            if self._in_q or self._in_closed is not None:
                return True
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return False
            self.inner.poll(min(0.02, remaining))

    def get(self) -> Message:
        while True:
            self._service()
            if self._in_q:
                item = self._in_q.popleft()
                if item is _CORRUPT_IN:
                    raise CorruptFrame(
                        f"chaos-corrupted frame on link {self.group!r}")
                return item
            if self._in_closed is not None:
                raise self._in_closed
            self.inner.poll(0.02)

    def fileno(self) -> int:
        # held frames (delay/reorder) and queued deliveries are
        # invisible to select(): degrade to slice polling while any
        # exist, so wait_readable keeps servicing the timers
        if self._in_q or self._delayed_in or self._delayed_out \
                or self._hold_in is not None:
            return -1
        return self.inner.fileno()

    def has_buffered(self) -> bool:
        return bool(self._in_q) or self._in_closed is not None \
            or self.inner.has_buffered()

    def close(self) -> None:
        self.inner.close()

    # transport passthroughs the managers/eventloop rely on
    def wire_stats(self) -> Optional[dict]:
        ws = getattr(self.inner, "wire_stats", None)
        return ws() if ws is not None else None


class _PoisonPill(Message):
    """Outbound corruption for transports without payload bytes: the
    wire tuple's kind is unregistered, so the peer's ``from_wire``
    fails exactly like an undecodable socket payload does."""

    def to_wire(self):
        return ("__corrupt__", {})


def find_chaos(channel: Channel) -> Optional[ChaosChannel]:
    """Walk a wrapper chain (ReliableChannel -> ChaosChannel ->
    transport) to the injector, if any — the partition scheduler's
    handle on a link."""
    seen = 0
    while channel is not None and seen < 8:
        if isinstance(channel, ChaosChannel):
            return channel
        channel = getattr(channel, "inner", None)
        seen += 1
    return None

"""Chaos plane: seeded fault injection, self-healing sessions,
coordinator crash-resume (DESIGN.md §15).

Acceptance anchors (ISSUE 8):
  * ``ChaosChannel`` misbehaves deterministically: the fault pattern is
    a pure function of (seed, group, direction, frame index) — two runs
    with the same spec produce identical delivered sequences and stats;
  * the reliable session layer (``ipc/session.py``) heals heavy
    drop/dup/reorder/corrupt/delay chaos into exactly-once, in-order
    delivery in both directions; corrupt frames burn the transport's
    bounded resync budget and close the channel when it runs dry;
  * dup/reorder-only chaos over the REAL socket backend is invisible to
    control: events, retune-lag accounting, staleness counters and
    liveness all match a clean run bit-for-bit at k=0 and k=2;
  * a chaos partition window is observationally identical to the
    simulator's ``Dropout`` at any staleness bound — the Fig. 6
    sequence with a partition spliced in still matches the sim exactly;
  * chaos off builds NONE of the machinery (wrapper-existence
    inertness) and every unsequenced wire shape stays byte-identical;
  * the coordinator journals its run state and a restarted loop
    (in-process hand-off AND a SIGKILLed subprocess) provably continues
    the Fig. 6 sequence from the journaled round;
  * a standalone socket worker that loses its TCP session rejoins with
    a bumped incarnation and no operator action;
  * satellites: jittered exponential reconnect backoff, fsync-before-
    rename journal durability (with an injected crash), partition purge
    of run-ahead buckets, and hello-timeout errors that name the
    endpoint.
"""
from __future__ import annotations

import os
import random
import signal
import subprocess
import sys
import threading
import time

import pytest

from repro.checkpoint.checkpointer import Checkpointer, RunJournal
from repro.core.control import ControlPlane, SpeedDeclinePolicy
from repro.core.control.telemetry import StepBuckets
from repro.core.simulator import (fig6_escalating_interference,
                                  stannis_3node_plan)
from repro.launch.worker import backoff_delays, connect_and_serve
from repro.obs import MetricsRegistry
from repro.runtime import (EventLoop, FaultAction, MANAGERS,
                           SocketExecutionManager, specs_from_plan)
from repro.runtime.ipc import (ChannelClosed, ChaosChannel, ChaosRates,
                               ChaosSpec, ChaosWindow, CorruptFrame,
                               PartitionWindow, ReliableChannel, find_chaos,
                               pipe_pair)
from repro.runtime.managers.base import (ExecutionManager, HandshakeTimeout,
                                         WorkerHandle)
from repro.runtime.messages import StepGrant
from repro.runtime.parity import fig6_chaos_parity, fig6_parity, run_sim
from repro.runtime.worker import WorkerSpec

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


def _events(cp: ControlPlane):
    return [(e.step, e.group, e.old_batch, e.new_batch, e.reason)
            for e in cp.events]


# ---------------------------------------------------------------------------
# ChaosSpec grammar
# ---------------------------------------------------------------------------


class TestChaosSpec:
    def test_parse_full_grammar(self):
        spec = ChaosSpec.parse(
            "seed=7,drop=0.01,send.dup=0.02,recv.delay=0.05,delay_s=0.01,"
            "window=5-25:drop=1.0,partition=xeon1@20-26,"
            "groups=xeon0|xeon1")
        assert spec.seed == 7
        assert spec.send.drop == spec.recv.drop == 0.01
        assert spec.send.dup == 0.02 and spec.recv.dup == 0.0
        assert spec.recv.delay == 0.05 and spec.send.delay == 0.0
        assert spec.send.delay_s == spec.recv.delay_s == 0.01
        assert spec.windows == [ChaosWindow(5, 25,
                                            ChaosRates(drop=1.0,
                                                       delay_s=0.02),
                                            ChaosRates(drop=1.0,
                                                       delay_s=0.02))]
        assert spec.partitions == [PartitionWindow("xeon1", 20, 26)]
        assert spec.groups == ("xeon0", "xeon1")
        assert spec.applies_to("xeon1") and not spec.applies_to("csd0")

    @pytest.mark.parametrize("bad", [
        "frobnicate=1",                  # unknown key
        "seed",                          # no '='
        "partition=xeon1",               # missing @start-end
        "partition=xeon1@20",            # missing -end
        "window=5-25:frobnicate=1.0",    # unknown window rate
        "up.drop=0.5",                   # unknown direction
        "send.frobnicate=0.5",           # unknown per-direction rate
    ])
    def test_parse_rejects_malformed_tokens(self, bad):
        with pytest.raises(ValueError):
            ChaosSpec.parse(bad)

    def test_window_selection_innermost_wins(self):
        spec = ChaosSpec.parse(
            "drop=0.1,window=5-25:drop=1.0,window=10-20:drop=0.5")
        assert spec.rates("send", 3, "g").drop == 0.1
        assert spec.rates("send", 7, "g").drop == 1.0
        assert spec.rates("send", 15, "g").drop == 0.5   # last listed wins
        assert spec.rates("send", 25, "g").drop == 0.1   # half-open end

    def test_group_scoped_window(self):
        spec = ChaosSpec(send=ChaosRates(drop=0.1))
        spec.windows.append(ChaosWindow(0, 10, ChaosRates(drop=1.0),
                                        ChaosRates(drop=1.0),
                                        group="xeon1"))
        assert spec.rates("send", 5, "xeon1").drop == 1.0
        assert spec.rates("send", 5, "xeon0").drop == 0.1

    def test_default_spec_is_reliability_only(self):
        spec = ChaosSpec()
        assert not spec.send.any() and not spec.recv.any()
        assert spec.applies_to("anything")


# ---------------------------------------------------------------------------
# ChaosChannel: seeded injection over a real transport
# ---------------------------------------------------------------------------


def _chaos_over_pipe(spec, group="g", budget=64):
    a_raw, b_raw = pipe_pair()
    a_raw.resync_budget = budget
    b_raw.resync_budget = budget
    return ChaosChannel(a_raw, spec, group), b_raw


def _drain(chan, out):
    while chan.poll(0.0):
        try:
            out.append(chan.get().step)
        except CorruptFrame:
            out.append("corrupt")
    return out


class TestChaosChannel:
    def test_inert_spec_passes_everything_through(self):
        cc, peer = _chaos_over_pipe(ChaosSpec())
        for i in range(20):
            cc.put(StepGrant(i))
        assert _drain(peer, []) == list(range(20))
        assert cc.chaos_stats() == {}
        cc.close()
        peer.close()

    def test_same_seed_same_fault_pattern(self):
        spec_text = "seed=13,drop=0.3,dup=0.2,reorder=0.2,corrupt=0.1"
        runs = []
        for _ in range(2):
            cc, peer = _chaos_over_pipe(ChaosSpec.parse(spec_text))
            for i in range(60):
                cc.put(StepGrant(i))
            runs.append((_drain(peer, []), cc.chaos_stats()))
            cc.close()
            peer.close()
        assert runs[0] == runs[1]
        # and a different seed perturbs the pattern
        cc, peer = _chaos_over_pipe(
            ChaosSpec.parse(spec_text.replace("seed=13", "seed=14")))
        for i in range(60):
            cc.put(StepGrant(i))
        assert _drain(peer, []) != runs[0][0]
        cc.close()
        peer.close()

    def test_partition_severs_both_directions_and_kills_inflight(self):
        # a long outbound delay parks a frame inside the injector: the
        # partition must kill it too (it is "on the wire")
        spec = ChaosSpec(send=ChaosRates(delay=1.0, delay_s=30.0))
        cc, peer = _chaos_over_pipe(spec)
        cc.put(StepGrant(1))             # held in the delay heap
        cc.set_partitioned(True)
        assert cc.partitioned
        assert cc.chaos_stats()["partition_dropped_inflight"] == 1
        cc.put(StepGrant(2))
        assert cc.chaos_stats()["partition_dropped_out"] == 1
        peer.put(StepGrant(3))
        assert not cc.poll(0.1)          # inbound swallowed at ingest
        assert cc.chaos_stats()["partition_dropped_in"] == 1
        cc.set_partitioned(False)
        assert not cc.partitioned
        peer.put(StepGrant(4))
        assert cc.poll(1.0) and cc.get() == StepGrant(4)
        stats = cc.chaos_stats()
        assert stats["partitions"] == 1 and stats["heals"] == 1
        cc.close()
        peer.close()

    def test_outbound_corruption_is_loud_and_budget_bounded(self):
        assert issubclass(CorruptFrame, ChannelClosed)
        spec = ChaosSpec(send=ChaosRates(corrupt=1.0))
        cc, peer = _chaos_over_pipe(spec, budget=2)
        for _ in range(3):
            cc.put(StepGrant(1))
        with pytest.raises(CorruptFrame):
            peer.get()
        with pytest.raises(CorruptFrame):
            peer.get()
        with pytest.raises(ChannelClosed) as ei:  # streak > budget
            peer.get()
        assert not isinstance(ei.value, CorruptFrame)
        cc.close()
        peer.close()

    def test_default_budget_zero_keeps_legacy_close(self):
        a_raw, b_raw = pipe_pair()       # resync_budget defaults to 0
        cc = ChaosChannel(a_raw, ChaosSpec(send=ChaosRates(corrupt=1.0)),
                          "g")
        cc.put(StepGrant(1))
        with pytest.raises(ChannelClosed) as ei:
            b_raw.get()
        assert not isinstance(ei.value, CorruptFrame)
        cc.close()
        b_raw.close()

    def test_find_chaos_walks_the_wrapper_chain(self):
        a_raw, b_raw = pipe_pair()
        cc = ChaosChannel(a_raw, ChaosSpec(), "g")
        rc = ReliableChannel(cc)
        assert find_chaos(rc) is cc
        assert find_chaos(b_raw) is None
        rc.close()
        b_raw.close()


# ---------------------------------------------------------------------------
# the reliable session layer
# ---------------------------------------------------------------------------


class TestReliableSession:
    def test_exactly_once_in_order_under_heavy_chaos(self):
        spec = ChaosSpec.parse("seed=3,drop=0.08,dup=0.08,reorder=0.08,"
                               "corrupt=0.04,delay=0.05,delay_s=0.005")
        a_raw, b_raw = pipe_pair()
        a_raw.resync_budget = 64
        b_raw.resync_budget = 64
        a = ReliableChannel(ChaosChannel(a_raw, spec, "g"))
        b = ReliableChannel(b_raw)
        n = 120
        got_ab, got_ba = [], []
        deadline = time.monotonic() + 60.0
        i = 0
        while time.monotonic() < deadline:
            if i < n:
                a.put(StepGrant(i))
                b.put(StepGrant(1000 + i))
                i += 1
            while b.poll(0.0):
                got_ab.append(b.get().step)
            while a.poll(0.0):
                got_ba.append(a.get().step)
            if (len(got_ab) == n and len(got_ba) == n
                    and not a.session_stats()["unacked"]
                    and not b.session_stats()["unacked"]):
                break
            a.poll(0.002)
            b.poll(0.002)
        assert got_ab == list(range(n))
        assert got_ba == [1000 + i for i in range(n)]
        healed = (a.stats["retransmits"] + b.stats["retransmits"]
                  + a.stats["fast_retransmits"] + b.stats["fast_retransmits"])
        assert healed > 0, "chaos this heavy must have forced retransmits"
        assert a.session_stats()["unacked"] == 0
        assert b.session_stats()["unacked"] == 0
        a.close()
        b.close()

    def test_unsequenced_frames_bypass_the_session(self):
        # rendezvous frames from an unwrapped peer (seq=-1) deliver
        # directly — the handshake predates the session on both ends
        a_raw, b_raw = pipe_pair()
        b = ReliableChannel(b_raw)
        a_raw.put(StepGrant(5))
        assert b.poll(1.0) and b.get() == StepGrant(5)
        b.close()
        a_raw.close()

    def test_stamping_copies_never_mutate_the_original(self):
        a_raw, b_raw = pipe_pair()
        a = ReliableChannel(a_raw)
        msg = StepGrant(3)
        a.put(msg)
        assert msg.seq == -1             # broadcasts are shared objects
        assert b_raw.get().seq == 0
        a.close()
        b_raw.close()

    def test_replay_buffer_overflow_is_a_loud_death(self):
        a_raw, b_raw = pipe_pair()
        a = ReliableChannel(a_raw, max_unacked=4)
        for i in range(4):
            a.put(StepGrant(i))          # peer never acks
        with pytest.raises(ChannelClosed, match="replay buffer"):
            a.put(StepGrant(4))
        a.close()
        b_raw.close()


# ---------------------------------------------------------------------------
# reconnect backoff (satellite 1)
# ---------------------------------------------------------------------------


class TestBackoff:
    def test_half_jitter_growth_and_cap(self):
        delays = backoff_delays(base=0.05, factor=2.0, cap=2.0,
                                rng=random.Random(0))
        nominal = 0.05
        for _ in range(12):
            d = next(delays)
            assert nominal / 2 <= d <= nominal
            nominal = min(nominal * 2.0, 2.0)
        assert nominal == 2.0            # capped, not unbounded

    def test_seeded_rng_makes_it_deterministic(self):
        a = backoff_delays(rng=random.Random(7))
        b = backoff_delays(rng=random.Random(7))
        assert [next(a) for _ in range(8)] == [next(b) for _ in range(8)]

    def test_first_retry_is_nearly_immediate(self):
        assert next(backoff_delays(rng=random.Random(1))) <= 0.05


# ---------------------------------------------------------------------------
# partition purge of run-ahead buckets (the step-exactness fix)
# ---------------------------------------------------------------------------


class TestStepBucketsDiscard:
    def test_discard_group_from_step(self):
        b = StepBuckets()
        for step, group in [(4, "a"), (5, "a"), (5, "b"), (6, "a")]:
            assert b.add(step, group, object())
        assert b.discard_group("a", 5) == 2
        assert set(b._buckets[5]) == {"b"}
        assert "a" in b._buckets[4]      # below the partition round
        assert not b._buckets.get(6)
        assert b.discard_group("a", 5) == 0   # idempotent


# ---------------------------------------------------------------------------
# journal durability (satellite 2)
# ---------------------------------------------------------------------------


class TestJournalDurability:
    def test_crash_at_rename_preserves_previous_entry(self, tmp_path,
                                                      monkeypatch):
        j = RunJournal(str(tmp_path))
        j.save(5, {"next_round": 5, "tag": "alpha"})

        def power_cut(src, dst):
            raise OSError("simulated crash at rename")

        monkeypatch.setattr(os, "replace", power_cut)
        with pytest.raises(OSError):
            j.save(6, {"next_round": 6, "tag": "beta"})
        monkeypatch.undo()
        assert j.load_latest() == {"next_round": 5, "tag": "alpha"}
        j.save(7, {"next_round": 7, "tag": "gamma"})  # and it recovers
        assert j.load_latest()["next_round"] == 7

    def test_manifest_fsynced_before_rename(self, tmp_path, monkeypatch):
        calls = []
        real_fsync, real_replace = os.fsync, os.replace

        def spy_fsync(fd):
            calls.append("fsync")
            return real_fsync(fd)

        def spy_replace(src, dst):
            calls.append("replace")
            return real_replace(src, dst)

        monkeypatch.setattr(os, "fsync", spy_fsync)
        monkeypatch.setattr(os, "replace", spy_replace)
        Checkpointer(str(tmp_path), async_save=False).save(
            1, {}, extras={"x": 1})
        idx = calls.index("replace")
        # npz + manifest + the tmp dir entry, all durable BEFORE the
        # rename publishes them; the parent directory after
        assert calls[:idx].count("fsync") >= 3
        assert "fsync" in calls[idx + 1:]

    def test_keep_k_and_torn_entry_skip(self, tmp_path):
        j = RunJournal(str(tmp_path), keep=3)
        for r in range(1, 6):
            j.save(r, {"next_round": r})
        assert j.entries() == [3, 4, 5]
        torn = tmp_path / "journal" / "step_00000005" / "manifest.json"
        torn.write_text("{torn")
        assert j.load_latest()["next_round"] == 4


# ---------------------------------------------------------------------------
# hello-timeout diagnostics (satellite 6)
# ---------------------------------------------------------------------------


class _NullManager(ExecutionManager):
    name = "null"

    def _launch(self, spec):
        raise NotImplementedError

    def kill(self, group):
        raise NotImplementedError

    def _join_all(self):
        pass


class TestHandshakeDiagnostics:
    def _handle(self):
        a, b = pipe_pair()
        spec = WorkerSpec(group="csd9", batch_size=8, capacity=4)
        return WorkerHandle(spec=spec, channel=a,
                            endpoint="10.9.8.7:5555"), b

    def test_timeout_names_group_and_endpoint(self):
        mgr = _NullManager(hello_timeout=0.05)
        handle, peer = self._handle()
        with pytest.raises(HandshakeTimeout) as ei:
            mgr._await_hello(handle)
        assert "'csd9'" in str(ei.value)
        assert "10.9.8.7:5555" in str(ei.value)
        handle.channel.close()
        peer.close()

    def test_eof_before_hello_names_group_and_endpoint(self):
        mgr = _NullManager(hello_timeout=1.0)
        handle, peer = self._handle()
        peer.close()
        with pytest.raises(HandshakeTimeout) as ei:
            mgr._await_hello(handle)
        assert "closed before Hello" in str(ei.value)
        assert "'csd9'" in str(ei.value) and "10.9.8.7:5555" in str(ei.value)
        handle.channel.close()

    def test_wrong_first_message_names_the_kind(self):
        mgr = _NullManager(hello_timeout=1.0)
        handle, peer = self._handle()
        peer.put(StepGrant(1))
        with pytest.raises(HandshakeTimeout, match="expected Hello"):
            mgr._await_hello(handle)
        handle.channel.close()
        peer.close()


# ---------------------------------------------------------------------------
# inertness: chaos off builds nothing, wire shapes stay legacy
# ---------------------------------------------------------------------------


class TestInertness:
    def test_no_chaos_builds_no_wrappers(self):
        mgr = MANAGERS["local"]()
        try:
            mgr.start(specs_from_plan(stannis_3node_plan()))
            for handle in mgr.workers.values():
                assert not isinstance(handle.channel, ReliableChannel)
                assert find_chaos(handle.channel) is None
                assert not handle.spec.session
        finally:
            mgr.shutdown()

    def test_unsequenced_wire_shape_has_no_seq(self):
        kind, fields = StepGrant(3).to_wire()
        assert kind == "grant" and "seq" not in fields


# ---------------------------------------------------------------------------
# Fig. 6 under chaos: the tentpole parity oracle
# ---------------------------------------------------------------------------


class TestFig6ChaosParity:
    @pytest.mark.parametrize("k", [0, 2])
    def test_local_chaos_is_invisible_to_control(self, k):
        metrics = MetricsRegistry()
        p = fig6_chaos_parity(manager="local", staleness=k,
                              chaos="seed=7,drop=0.02,dup=0.02,"
                                    "reorder=0.01",
                              metrics=metrics)
        assert p["match"], (p["sim"], p["runtime"])
        # the session healed real injected loss (scraped to metrics)
        assert metrics.get("session.sent").value > 0
        chaos_total = sum(
            metrics.get(f"chaos.{key}").value
            for key in ("dropped_out", "dropped_in", "dup_out", "dup_in",
                        "reordered_out", "reordered_in")
            if metrics.get(f"chaos.{key}") is not None)
        assert chaos_total > 0

    @pytest.mark.parametrize("k", [0, 2])
    def test_local_partition_mirrors_sim_dropout(self, k):
        p = fig6_chaos_parity(manager="local", staleness=k,
                              chaos="seed=7,drop=0.01,dup=0.01,"
                                    "partition=xeon1@30-38")
        assert p["match"], (p["sim"], p["runtime"])
        reasons = [e[4] for e in p["runtime"]]
        assert "failure" in reasons and "recover" in reasons

    @pytest.mark.parametrize("k", [0, 2])
    def test_socket_dup_reorder_identical_to_clean_run(self, k):
        # satellite 3: lossless pathologies (dup + reorder) at the
        # SocketChannel layer must leave round stats, liveness and
        # retune-lag accounting identical to a clean run
        chaos = fig6_chaos_parity(manager="socket", staleness=k,
                                  chaos="seed=5,dup=0.05,reorder=0.05")
        clean = fig6_parity(manager="socket", staleness=k)
        assert chaos["match"] and clean["match"]
        assert chaos["runtime"] == clean["runtime"]
        rc, rl = chaos["result"], clean["result"]
        assert rc.retune_lags == rl.retune_lags == [k + 1] * 2
        assert rc.stale_reports == rl.stale_reports
        assert rc.reports_total == rl.reports_total
        assert not any(e[4] in ("failure", "recover")
                       for e in chaos["runtime"])

    @pytest.mark.parametrize("k", [0, 2])
    def test_socket_chaos_with_partition(self, k):
        # the CI chaos cell's assertion, at both staleness bounds:
        # seeded loss healed by the session AND a partition window
        # mirrored as a sim Dropout, over real TCP
        p = fig6_chaos_parity(manager="socket", staleness=k,
                              chaos="seed=7,drop=0.02,dup=0.02,"
                                    "reorder=0.01,partition=xeon1@30-38")
        assert p["match"], (p["sim"], p["runtime"])


# ---------------------------------------------------------------------------
# coordinator crash-resume
# ---------------------------------------------------------------------------


def _fresh_fig6_loop(staleness=0):
    plan = stannis_3node_plan()
    cp = ControlPlane(plan, [SpeedDeclinePolicy()])
    mgr = MANAGERS["local"]()
    loop = EventLoop(cp, mgr, round_timeout=2.0, staleness=staleness)
    return cp, mgr, loop


def _resume_and_finish(run_dir, state, steps=45):
    """Second life: fresh control plane + workers, restore, run out."""
    cp, mgr, loop = _fresh_fig6_loop()
    start = loop.restore(state)
    try:
        mgr.start(specs_from_plan(cp.plan, fig6_escalating_interference()))
        loop.run(steps, start=start,
                 journal=RunJournal(run_dir), journal_every=1)
    finally:
        loop.shutdown()
    return cp, start


class TestCrashResume:
    def _first_life(self, run_dir, rounds=20):
        cp, mgr, loop = _fresh_fig6_loop()
        journal = RunJournal(run_dir)
        try:
            mgr.start(specs_from_plan(cp.plan,
                                      fig6_escalating_interference()))
            loop.run(rounds, journal=journal, journal_every=1)
        finally:
            loop.shutdown()
        return journal

    def test_inprocess_resume_continues_fig6(self, tmp_path):
        run_dir = str(tmp_path)
        journal = self._first_life(run_dir, rounds=20)
        state = journal.load_latest()
        cp2, start = _resume_and_finish(run_dir, state)
        assert start == 20
        assert _events(cp2) == run_sim(fig6_escalating_interference(),
                                       steps=45)

    def test_resume_from_older_entry_is_deterministic(self, tmp_path):
        # replaying rounds the dead coordinator already ran must
        # converge on the same event stream (report-only workers are
        # pure functions of step and spec)
        run_dir = str(tmp_path)
        journal = self._first_life(run_dir, rounds=20)
        oldest = journal.entries()[0]    # keep-k leaves 18,19,20
        assert oldest < 20
        ck = Checkpointer(os.path.join(run_dir, RunJournal.SUBDIR))
        _, state = ck.restore(oldest, {})
        cp2, start = _resume_and_finish(run_dir, state)
        assert start == oldest
        assert _events(cp2) == run_sim(fig6_escalating_interference(),
                                       steps=45)

    def test_staleness_mismatch_is_rejected(self, tmp_path):
        _, _, loop0 = _fresh_fig6_loop(staleness=0)
        state = loop0._journal_state(3)
        _, _, loop2 = _fresh_fig6_loop(staleness=2)
        with pytest.raises(ValueError, match="staleness"):
            loop2.restore(state)

    def test_sigkilled_coordinator_resumes_mid_fig6(self, tmp_path):
        # the real thing: a coordinator subprocess journaling every
        # round is SIGKILLed mid-run; a fresh loop restores the newest
        # intact entry and finishes the paper's exact sequence
        run_dir = str(tmp_path / "run")
        driver = tmp_path / "driver.py"
        driver.write_text(
            "from repro.checkpoint.checkpointer import RunJournal\n"
            "from repro.core.control import ControlPlane, "
            "SpeedDeclinePolicy\n"
            "from repro.core.simulator import "
            "fig6_escalating_interference, stannis_3node_plan\n"
            "from repro.runtime import EventLoop, MANAGERS, "
            "specs_from_plan\n"
            "plan = stannis_3node_plan()\n"
            "cp = ControlPlane(plan, [SpeedDeclinePolicy()])\n"
            "specs = specs_from_plan(plan, fig6_escalating_interference(),"
            " step_delay_s=0.05)\n"
            "mgr = MANAGERS['local']()\n"
            "loop = EventLoop(cp, mgr, round_timeout=5.0)\n"
            "mgr.start(specs)\n"
            f"loop.run(45, journal=RunJournal({run_dir!r}), "
            "journal_every=1)\n")
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.Popen([sys.executable, str(driver)], env=env)
        journal = RunJournal(run_dir)
        try:
            deadline = time.monotonic() + 60.0
            while time.monotonic() < deadline:
                entries = journal.entries()
                if entries and entries[-1] >= 8:
                    break
                if proc.poll() is not None:
                    pytest.fail("coordinator exited before the kill")
                time.sleep(0.02)
            else:
                pytest.fail("coordinator never journaled 8 rounds")
            os.kill(proc.pid, signal.SIGKILL)
        finally:
            proc.wait(timeout=30)
        state = journal.load_latest()
        assert state is not None
        assert 8 <= state["next_round"] < 45, "kill missed the mid-run window"
        cp2, start = _resume_and_finish(run_dir, state)
        assert start == state["next_round"]
        assert _events(cp2) == run_sim(fig6_escalating_interference(),
                                       steps=45)


# ---------------------------------------------------------------------------
# standalone worker self-heal
# ---------------------------------------------------------------------------


class TestWorkerSelfHeal:
    def test_socket_worker_rejoins_after_connection_loss(self):
        plan = stannis_3node_plan()
        cp = ControlPlane(plan, [SpeedDeclinePolicy()], liveness_timeout=3)
        mgr = SocketExecutionManager(spawn=False, hello_timeout=30.0)
        threads = []
        for group in sorted(plan.batch_sizes()):
            t = threading.Thread(
                target=connect_and_serve, args=(mgr.endpoint, group),
                kwargs={"resume": True, "retry_for": 30.0,
                        "rng": random.Random(hash(group) & 0xFFFF)},
                daemon=True, name=f"standalone-{group}")
            t.start()
            threads.append(t)
        loop = EventLoop(cp, mgr, round_timeout=2.0)
        try:
            mgr.start(specs_from_plan(plan))
            # severing the coordinator side of the TCP session is the
            # kill for an external worker: the worker sees EOF and must
            # rejoin on its own (backoff + incarnation bump + replay)
            res = loop.run(30, faults=[FaultAction(5, "kill", "xeon1")])
        finally:
            loop.shutdown()
        for t in threads:
            t.join(timeout=20)
            assert not t.is_alive(), f"{t.name} never exited"
        assert res.rounds == 30
        assert mgr.workers["xeon1"].incarnation >= 1, \
            "rejoin did not bump the incarnation"
        # the outage is at most a couple of rounds of xeon1's reports
        assert res.reports_total >= 30 * 3 - 6
